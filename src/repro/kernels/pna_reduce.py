"""Blockwise multi-aggregator reduction Pallas kernels — PNA on the MXU path.

PNA aggregates per-edge messages msg_e = relu(xd[dst_e] + xs[src_e]) with
mean / min / max (+ degree scalers). The per-edge transform decomposes into
two *per-node* linear maps (xd = x_all @ w1_dst, xs = x_all @ w1_src + b1),
so — like the fused gather kernel (`fused.py`) — each bn x bn adjacency
block can stream its destination rows through VMEM and reduce without
materializing the [E, f] message matrix: for destination row a the whole
[bn, f] message tile relu(xd[a] + xs_block) is formed on the VPU and
reduced against the multiplicity row m_a* of the unit-weight BCSR block.

Forward (`pna_reduce_fwd`), grid (R, F/bd, K, bn) with the destination row
innermost: running (sum, min, max, count) state persists in VMEM scratch
across the (K, row) dimensions — the same cross-grid online-state design
as the edge-softmax kernel. Tie *counts* at the running min/max are
maintained online too (multiplicity-weighted), because the backward pass
distributes min/max cotangents evenly across ties — exactly matching
`jax.ops.segment_min/max`'s even-split gradient.

Backward = one pass per block structure:
  * `pna_reduce_bwd_row` (forward blocks)    -> dxd (destination sums)
  * `pna_reduce_bwd_col` (transposed blocks) -> dxs (source sums)
Both recompute messages blockwise (bit-identical f32 arithmetic, so tie
detection against the saved min/max is exact) and apply
    dmsg = relu'(z) * m * (g_sum + tie_min * g_min/c_min
                                 + tie_max * g_max/c_max).

All internal compute is float32; callers pad to tile boundaries (see
`ops.pna_reduce`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 1e30      # f32-internal min/max sentinel (kernels compute in f32)


def _fwd_kernel(cols_ref, xd_ref, xs_ref, mrow_ref, s_ref, mn_ref, mx_ref,
                cnt_ref, cmin_ref, cmax_ref,
                s_acc, mn_acc, mx_acc, cnt_scr, cmin_acc, cmax_acc):
    k = pl.program_id(2)
    a = pl.program_id(3)

    @pl.when((k == 0) & (a == 0))
    def _init():
        s_acc[...] = jnp.zeros_like(s_acc)
        mn_acc[...] = jnp.full_like(mn_acc, BIG)
        mx_acc[...] = jnp.full_like(mx_acc, -BIG)
        cnt_scr[...] = jnp.zeros_like(cnt_scr)
        cmin_acc[...] = jnp.zeros_like(cmin_acc)
        cmax_acc[...] = jnp.zeros_like(cmax_acc)

    m = mrow_ref[0, 0, 0, :]                        # [bn] multiplicities
    xd_a = xd_ref[pl.ds(a, 1), :].astype(jnp.float32)   # [1, bd]
    xs = xs_ref[...].astype(jnp.float32)            # [bn, bd] source tile
    msg = jnp.maximum(xd_a + xs, 0.0)               # [bn, bd]
    valid = (m > 0)[:, None]

    row = pl.ds(a, 1)
    s_acc[row, :] += (m[:, None] * msg).sum(axis=0, keepdims=True)
    cnt_scr[row, :] += m.sum()[None, None]

    # online min/max with multiplicity-weighted tie counts: a strictly
    # better block value resets the count, an equal one adds to it
    mn_blk = jnp.where(valid, msg, BIG).min(axis=0, keepdims=True)
    new_mn = jnp.minimum(mn_acc[row, :], mn_blk)
    here_mn = (m[:, None] * jnp.where(valid & (msg == new_mn), 1.0, 0.0)
               ).sum(axis=0, keepdims=True)
    cmin_acc[row, :] = jnp.where(mn_acc[row, :] == new_mn,
                                 cmin_acc[row, :], 0.0) + here_mn
    mn_acc[row, :] = new_mn

    mx_blk = jnp.where(valid, msg, -BIG).max(axis=0, keepdims=True)
    new_mx = jnp.maximum(mx_acc[row, :], mx_blk)
    here_mx = (m[:, None] * jnp.where(valid & (msg == new_mx), 1.0, 0.0)
               ).sum(axis=0, keepdims=True)
    cmax_acc[row, :] = jnp.where(mx_acc[row, :] == new_mx,
                                 cmax_acc[row, :], 0.0) + here_mx
    mx_acc[row, :] = new_mx

    @pl.when((k == pl.num_programs(2) - 1) & (a == pl.num_programs(3) - 1))
    def _finish():
        has = cnt_scr[...] > 0                      # [bn, 1]
        s_ref[...] = s_acc[...]
        mn_ref[...] = jnp.where(has, mn_acc[...], 0.0)
        mx_ref[...] = jnp.where(has, mx_acc[...], 0.0)
        cnt_ref[0, :] = cnt_scr[:, 0]
        cmin_ref[...] = cmin_acc[...]
        cmax_ref[...] = cmax_acc[...]


@functools.partial(jax.jit, static_argnames=("bn", "bd", "interpret"))
def pna_reduce_fwd(xd: jnp.ndarray, xs: jnp.ndarray,
                   ublk_vals: jnp.ndarray, blk_cols: jnp.ndarray, *,
                   bn: int = 128, bd: int = 128, interpret: bool = True):
    """Blockwise sum/min/max/count of msg = relu(xd[dst] + xs[src]).

    xd [R*bn, Fp] destination-side transform; xs [C*bn, Fp] source-side;
    ublk_vals [R, K, bn, bn] edge multiplicities; blk_cols [R, K].
    Returns (s, mn, mx, cnt, cmin, cmax): s/mn/mx/cmin/cmax [R*bn, Fp]
    f32 (mn/mx are 0 for empty rows), cnt [R*bn] f32. cmin/cmax are the
    multiplicity-weighted tie counts at the min/max, consumed by the
    backward kernels' even-split gradient.
    """
    R, K, bn_, bn2 = ublk_vals.shape
    assert bn_ == bn and bn2 == bn, (ublk_vals.shape, bn)
    Rp, Fp = xd.shape
    assert Rp == R * bn and Fp % bd == 0, (xd.shape, bn, bd)
    assert xs.shape[1] == Fp

    grid = (R, Fp // bd, K, bn)
    tile = lambda r, f, k, a, cols: (r, f)                     # noqa: E731
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), tile),
            pl.BlockSpec((bn, bd), lambda r, f, k, a, cols: (cols[r, k], f)),
            pl.BlockSpec((1, 1, 1, bn),
                         lambda r, f, k, a, cols: (r, k, a, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, bd), tile),
            pl.BlockSpec((bn, bd), tile),
            pl.BlockSpec((bn, bd), tile),
            pl.BlockSpec((1, bn), lambda r, f, k, a, cols: (r, 0)),
            pl.BlockSpec((bn, bd), tile),
            pl.BlockSpec((bn, bd), tile),
        ],
        scratch_shapes=[pltpu.VMEM((bn, bd), jnp.float32),
                        pltpu.VMEM((bn, bd), jnp.float32),
                        pltpu.VMEM((bn, bd), jnp.float32),
                        pltpu.VMEM((bn, 1), jnp.float32),
                        pltpu.VMEM((bn, bd), jnp.float32),
                        pltpu.VMEM((bn, bd), jnp.float32)],
    )
    s, mn, mx, cnt, cmin, cmax = pl.pallas_call(
        _fwd_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((Rp, Fp), jnp.float32),
                   jax.ShapeDtypeStruct((Rp, Fp), jnp.float32),
                   jax.ShapeDtypeStruct((Rp, Fp), jnp.float32),
                   jax.ShapeDtypeStruct((R, bn), jnp.float32),
                   jax.ShapeDtypeStruct((Rp, Fp), jnp.float32),
                   jax.ShapeDtypeStruct((Rp, Fp), jnp.float32)],
        interpret=interpret,
    )(blk_cols, xd, xs, ublk_vals)
    return s, mn, mx, cnt.reshape(Rp), cmin, cmax


def _dmsg(msg, z, m, gs, gmn, gmx, mn, mx, cmin, cmax):
    """Even-split cotangent of (sum, min, max) w.r.t. one message tile.
    All stat operands broadcast against msg [*, bd]; m is the
    multiplicity aligned with msg's leading axis."""
    valid = (m > 0)[:, None]
    tie_mn = jnp.where(valid & (msg == mn), 1.0, 0.0)
    tie_mx = jnp.where(valid & (msg == mx), 1.0, 0.0)
    grad = gs + tie_mn * gmn / jnp.maximum(cmin, 1.0) \
        + tie_mx * gmx / jnp.maximum(cmax, 1.0)
    return jnp.where(z > 0, 1.0, 0.0) * m[:, None] * grad


def _bwd_row_kernel(cols_ref, xd_ref, xs_ref, mrow_ref, gs_ref, gmn_ref,
                    gmx_ref, mn_ref, mx_ref, cmin_ref, cmax_ref,
                    dxd_ref, acc):
    k = pl.program_id(2)
    a = pl.program_id(3)

    @pl.when((k == 0) & (a == 0))
    def _init():
        acc[...] = jnp.zeros_like(acc)

    m = mrow_ref[0, 0, 0, :]                        # [bn] over sources
    row = pl.ds(a, 1)
    z = xd_ref[row, :].astype(jnp.float32) + \
        xs_ref[...].astype(jnp.float32)             # [bn_src, bd]
    msg = jnp.maximum(z, 0.0)
    d = _dmsg(msg, z, m, gs_ref[row, :], gmn_ref[row, :], gmx_ref[row, :],
              mn_ref[row, :], mx_ref[row, :], cmin_ref[row, :],
              cmax_ref[row, :])
    acc[row, :] += d.sum(axis=0, keepdims=True)

    @pl.when((k == pl.num_programs(2) - 1) & (a == pl.num_programs(3) - 1))
    def _finish():
        dxd_ref[...] = acc[...]


@functools.partial(jax.jit, static_argnames=("bn", "bd", "interpret"))
def pna_reduce_bwd_row(xd, xs, gs, gmn, gmx, mn, mx, cmin, cmax,
                       ublk_vals, blk_cols, *, bn: int = 128,
                       bd: int = 128, interpret: bool = True):
    """Destination-side cotangent dxd [R*bn, Fp] = sum_src dmsg over the
    forward block structure. gs/gmn/gmx are the (s, mn, mx) cotangents;
    mn/mx/cmin/cmax are the forward kernel's saved stats."""
    R, K, bn_, _ = ublk_vals.shape
    assert bn_ == bn
    Rp, Fp = xd.shape
    assert Rp == R * bn and Fp % bd == 0

    grid = (R, Fp // bd, K, bn)
    tile = lambda r, f, k, a, cols: (r, f)                     # noqa: E731
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), tile),
            pl.BlockSpec((bn, bd), lambda r, f, k, a, cols: (cols[r, k], f)),
            pl.BlockSpec((1, 1, 1, bn),
                         lambda r, f, k, a, cols: (r, k, a, 0)),
        ] + [pl.BlockSpec((bn, bd), tile)] * 7,
        out_specs=pl.BlockSpec((bn, bd), tile),
        scratch_shapes=[pltpu.VMEM((bn, bd), jnp.float32)],
    )
    return pl.pallas_call(
        _bwd_row_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Rp, Fp), jnp.float32),
        interpret=interpret,
    )(blk_cols, xd, xs, ublk_vals, gs, gmn, gmx, mn, mx, cmin, cmax)


def _bwd_col_kernel(colst_ref, xs_ref, xd_ref, mrow_ref, gs_ref, gmn_ref,
                    gmx_ref, mn_ref, mx_ref, cmin_ref, cmax_ref,
                    dxs_ref, acc):
    k = pl.program_id(2)
    s_row = pl.program_id(3)

    @pl.when((k == 0) & (s_row == 0))
    def _init():
        acc[...] = jnp.zeros_like(acc)

    # transposed block: rows = sources, columns = destinations; all stat
    # tiles are destination-space (fetched via the transposed column ids)
    m = mrow_ref[0, 0, 0, :]                        # [bn] over destinations
    row = pl.ds(s_row, 1)
    z = xs_ref[row, :].astype(jnp.float32) + \
        xd_ref[...].astype(jnp.float32)             # [bn_dst, bd]
    msg = jnp.maximum(z, 0.0)
    d = _dmsg(msg, z, m, gs_ref[...], gmn_ref[...], gmx_ref[...],
              mn_ref[...], mx_ref[...], cmin_ref[...], cmax_ref[...])
    acc[row, :] += d.sum(axis=0, keepdims=True)

    @pl.when((k == pl.num_programs(2) - 1) & (s_row == pl.num_programs(3) - 1))
    def _finish():
        dxs_ref[...] = acc[...]


@functools.partial(jax.jit, static_argnames=("bn", "bd", "interpret"))
def pna_reduce_bwd_col(xd, xs, gs, gmn, gmx, mn, mx, cmin, cmax,
                       ublk_vals_t, blk_cols_t, *, bn: int = 128,
                       bd: int = 128, interpret: bool = True):
    """Source-side cotangent dxs [C*bn, Fp] = sum_dst dmsg over the
    *transposed* block structure (destination-space stat tiles are fetched
    through the transposed column ids)."""
    R_t, K_t, bn_, _ = ublk_vals_t.shape
    assert bn_ == bn
    Cp, Fp = xs.shape
    assert Cp == R_t * bn and Fp % bd == 0

    grid = (R_t, Fp // bd, K_t, bn)
    tile = lambda r, f, k, a, cols: (r, f)                     # noqa: E731
    col_tile = lambda r, f, k, a, cols: (cols[r, k], f)        # noqa: E731
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), tile),
            pl.BlockSpec((bn, bd), col_tile),
            pl.BlockSpec((1, 1, 1, bn),
                         lambda r, f, k, a, cols: (r, k, a, 0)),
        ] + [pl.BlockSpec((bn, bd), col_tile)] * 7,
        out_specs=pl.BlockSpec((bn, bd), tile),
        scratch_shapes=[pltpu.VMEM((bn, bd), jnp.float32)],
    )
    return pl.pallas_call(
        _bwd_col_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Cp, Fp), jnp.float32),
        interpret=interpret,
    )(blk_cols_t, xs, xd, ublk_vals_t, gs, gmn, gmx, mn, mx, cmin, cmax)
