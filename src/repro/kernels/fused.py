"""Fused history-gather + block-CSR SpMM Pallas kernel (GAS aggregation).

The unfused GAS layer materializes

    x_all = concat([x_in, pull(table, halo_nodes) * halo_mask, 0])

and then runs the BCSR SpMM over x_all — a full halo gather plus a full
concatenate copy of the layer input, per layer, per batch, that exist only
to be read once by the matmul. This kernel removes both: the virtual x_all
is never built. A scalar-prefetched *gather plan* (sel/xrow/trow, one entry
per adjacency-block row, see `gather_plan`) tells each grid step where
virtual column `blk_cols[r, k] * bn + row` actually lives:

    sel == 0 : in-batch  -> x_in[xrow]   (current layer activations)
    sel == 1 : halo      -> table[trow]  (historical embedding, read
                                          directly out of the history table)
    sel == 2 : masked halo / dummy / padding -> exact zeros

Grid (R, D/bd, K, bn): the innermost axis streams the bn rows of one
adjacency block's input tile into a VMEM scratch buffer — Pallas
double-buffers the per-row HBM->VMEM DMAs, the TPU analogue of PyGAS's
CUDA-stream gathers — and on the block's last row the bn x bn adjacency
block multiplies the gathered tile on the MXU, accumulating into the
output tile in fp32.

Quantized histories (`scales` given): the table holds symmetric per-row
int8 rows and the per-row f32 scale vector rides along as a FOURTH
scalar-prefetch operand. The dequant multiply `table[trow] * scale[trow]`
is fused into the halo-column load on the VPU, between the int8 row DMA
and the MXU contraction — the f32 halo tensor never exists in HBM, and
the table's HBM traffic is int8 bytes only (~4x less than the f32 path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def gather_plan(blk_cols: jnp.ndarray, halo_nodes: jnp.ndarray,
                halo_mask: jnp.ndarray, n_in: int, n_table: int,
                bn: int):
    """Per-(block, row) source plan for `gather_spmm` (module docstring).

    Returns (sel, xrow, trow), each [R, K, bn] int32, computed from the
    block column ids and the batch's halo index vector. Cheap (R*K*bn
    elements) and jit-traceable — runs on device inside the train step.
    """
    row = jnp.arange(bn, dtype=jnp.int32)
    v = blk_cols[:, :, None].astype(jnp.int32) * bn + row    # virtual column
    max_h = halo_nodes.shape[0]
    is_in = v < n_in
    hidx = jnp.clip(v - n_in, 0, max_h - 1)
    halo_ok = (v >= n_in) & (v < n_in + max_h) & jnp.take(halo_mask, hidx)
    xrow = jnp.where(is_in, v, 0).astype(jnp.int32)
    trow = jnp.where(halo_ok,
                     jnp.clip(jnp.take(halo_nodes, hidx), 0, n_table - 1),
                     0).astype(jnp.int32)
    sel = jnp.where(is_in, 0, jnp.where(halo_ok, 1, 2)).astype(jnp.int32)
    return sel, xrow, trow


def _kernel(sel_ref, xrow_ref, trow_ref, x_ref, tbl_ref, vals_ref, out_ref,
            gx_ref):
    r = pl.program_id(0)
    k = pl.program_id(2)
    row = pl.program_id(3)

    @pl.when((k == 0) & (row == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # route this virtual row: in-batch activations, history table, or zero
    s = sel_ref[r, k, row]
    xr = x_ref[0, :].astype(jnp.float32)
    tr = tbl_ref[0, :].astype(jnp.float32)
    val = jnp.where(s == 0, xr, jnp.where(s == 1, tr, 0.0))
    gx_ref[pl.ds(row, 1), :] = val[None, :]

    @pl.when(row == pl.num_programs(3) - 1)
    def _accumulate():
        out_ref[...] += jnp.dot(vals_ref[0, 0], gx_ref[...],
                                preferred_element_type=jnp.float32)


def _kernel_dq(sel_ref, xrow_ref, trow_ref, scl_ref, x_ref, tbl_ref,
               vals_ref, out_ref, gx_ref):
    # the dequantizing twin of `_kernel` above — identical routing and
    # accumulation except for the scale multiply on the table row (Pallas
    # kernel signatures are positional over the scalar-prefetch operands,
    # so the two bodies cannot share one definition). Any change to the
    # sel routing / init / accumulate logic MUST be applied to both.
    r = pl.program_id(0)
    k = pl.program_id(2)
    row = pl.program_id(3)

    @pl.when((k == 0) & (row == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # route this virtual row: in-batch activations, history table
    # (dequantized in place: int8 row DMA -> VPU scale multiply), or zero
    s = sel_ref[r, k, row]
    xr = x_ref[0, :].astype(jnp.float32)
    tr = tbl_ref[0, :].astype(jnp.float32) * scl_ref[trow_ref[r, k, row]]
    val = jnp.where(s == 0, xr, jnp.where(s == 1, tr, 0.0))
    gx_ref[pl.ds(row, 1), :] = val[None, :]

    @pl.when(row == pl.num_programs(3) - 1)
    def _accumulate():
        out_ref[...] += jnp.dot(vals_ref[0, 0], gx_ref[...],
                                preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bn", "bd", "interpret"))
def gather_spmm(x_in: jnp.ndarray, table: jnp.ndarray,
                blk_vals: jnp.ndarray, blk_cols: jnp.ndarray,
                sel: jnp.ndarray, xrow: jnp.ndarray, trow: jnp.ndarray,
                scales: jnp.ndarray = None,
                *, bn: int = 128, bd: int = 128,
                interpret: bool = True) -> jnp.ndarray:
    """out [R*bn, D] = A @ [x_in ; dequant(table)[halo] ; 0] without
    building the bracket. x_in [n_in, D] / table [N, D] with D % bd == 0;
    xrow/trow must be pre-clipped to their source's row range (see
    `gather_plan`). With `scales` [N] f32 the table rows are int8 and
    dequantized in-kernel (module docstring). Output is fp32 (MXU-native
    accumulation); the caller casts."""
    R, K, bn_, bn2 = blk_vals.shape
    assert bn_ == bn and bn2 == bn, (blk_vals.shape, bn)
    D = x_in.shape[1]
    assert D % bd == 0 and table.shape[1] == D, (x_in.shape, table.shape, bd)
    assert sel.shape == (R, K, bn), (sel.shape, (R, K, bn))

    grid = (R, D // bd, K, bn)
    n_pref = 3 if scales is None else 4
    # index maps take one trailing ref per scalar-prefetch operand
    if scales is None:
        in_specs = [
            pl.BlockSpec((1, bd),
                         lambda r, d, k, row, sel, xrow, trow:
                         (xrow[r, k, row], d)),
            pl.BlockSpec((1, bd),
                         lambda r, d, k, row, sel, xrow, trow:
                         (trow[r, k, row], d)),
            pl.BlockSpec((1, 1, bn, bn),
                         lambda r, d, k, row, sel, xrow, trow: (r, k, 0, 0)),
        ]
        operands = (sel, xrow, trow, x_in, table, blk_vals)
        kernel = _kernel
    else:
        assert scales.shape == (table.shape[0],), (scales.shape,
                                                   table.shape)
        in_specs = [
            pl.BlockSpec((1, bd),
                         lambda r, d, k, row, sel, xrow, trow, scl:
                         (xrow[r, k, row], d)),
            pl.BlockSpec((1, bd),
                         lambda r, d, k, row, sel, xrow, trow, scl:
                         (trow[r, k, row], d)),
            pl.BlockSpec((1, 1, bn, bn),
                         lambda r, d, k, row, sel, xrow, trow, scl:
                         (r, k, 0, 0)),
        ]
        operands = (sel, xrow, trow, scales, x_in, table, blk_vals)
        kernel = _kernel_dq
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_pref,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bn, bd),
                               lambda r, d, k, row, *_: (r, d)),
        scratch_shapes=[pltpu.VMEM((bn, bd), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R * bn, D), jnp.float32),
        interpret=interpret,
    )(*operands)
