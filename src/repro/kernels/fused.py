"""Fused history-gather + block-CSR SpMM Pallas kernel (GAS aggregation).

The unfused GAS layer materializes

    x_all = concat([x_in, pull(table, halo_nodes) * halo_mask, 0])

and then runs the BCSR SpMM over x_all — a full halo gather plus a full
concatenate copy of the layer input, per layer, per batch, that exist only
to be read once by the matmul. This kernel removes both: the virtual x_all
is never built. A scalar-prefetched *gather plan* (sel/xrow/trow, one entry
per adjacency-block row, see `gather_plan`) tells each grid step where
virtual column `blk_cols[r, k] * bn + row` actually lives:

    sel == 0 : in-batch  -> x_in[xrow]   (current layer activations)
    sel == 1 : halo      -> table[trow]  (historical embedding, read
                                          directly out of the history table)
    sel == 2 : masked halo / dummy / padding -> exact zeros

Grid (R, D/bd, K): each step owns one bn x bn adjacency block. The
gathered-row DMAs are HAND-PIPELINED with `pltpu.make_async_copy`
multiple-buffering — x_in and the history table stay in HBM
(`pltpu.ANY`), and each step (a) waits on the double-buffer slot that
block k's rows were prefetched into, (b) immediately starts the row DMAs
for block k+1 into the other slot, and only then (c) routes/dequantizes
the staged rows and contracts the bn x bn block on the MXU. The history
row transfers for block k+1 therefore fly while block k multiplies — the
TPU analogue of PyGAS's concurrent CUDA-stream gathers, explicit instead
of relying on Pallas's automatic per-BlockSpec pipelining (which could
only overlap one row at a time).

Quantized histories (`scales` given): the table holds symmetric per-row
int8 rows; only int8 bytes cross HBM for halo columns (the staging buffer
is int8 too). The per-row dequant scale is pre-gathered into a dense
[R, K, bn] operand (`rscl = scales[trow]`) so the dequant multiply
`staged_int8 * scale` runs as one VPU op on the staged tile, between the
DMA wait and the MXU contraction — the f32 halo tensor never exists in
HBM, and the table's HBM traffic is int8 bytes only (~4x less than the
f32 path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def gather_plan(blk_cols: jnp.ndarray, halo_nodes: jnp.ndarray,
                halo_mask: jnp.ndarray, n_in: int, n_table: int,
                bn: int):
    """Per-(block, row) source plan for `gather_spmm` (module docstring).

    Returns (sel, xrow, trow), each [R, K, bn] int32, computed from the
    block column ids and the batch's halo index vector. Cheap (R*K*bn
    elements) and jit-traceable — runs on device inside the train step.
    """
    row = jnp.arange(bn, dtype=jnp.int32)
    v = blk_cols[:, :, None].astype(jnp.int32) * bn + row    # virtual column
    max_h = halo_nodes.shape[0]
    is_in = v < n_in
    hidx = jnp.clip(v - n_in, 0, max_h - 1)
    halo_ok = (v >= n_in) & (v < n_in + max_h) & jnp.take(halo_mask, hidx)
    xrow = jnp.where(is_in, v, 0).astype(jnp.int32)
    trow = jnp.where(halo_ok,
                     jnp.clip(jnp.take(halo_nodes, hidx), 0, n_table - 1),
                     0).astype(jnp.int32)
    sel = jnp.where(is_in, 0, jnp.where(halo_ok, 1, 2)).astype(jnp.int32)
    return sel, xrow, trow


def _row_dmas(sel_ref, xrow_ref, trow_ref, x_ref, tbl_ref, sx_ref, st_ref,
              sem_ref, r, d, blk, slot, bn, bd, start, full_tbl_row=False):
    """Issue (start=True) or drain (start=False) the bn gathered-row DMAs
    of adjacency block (r, blk) into double-buffer slot `slot`.

    Each virtual row moves with ONE `pltpu.make_async_copy`: sel==0 rows
    from x_in (f32) into the `sx` buffer, sel==1 rows from the history
    table (f32/bf16/int8, or the whole uint8 code row for vq —
    `full_tbl_row`) into the `st` buffer, sel==2 rows move nothing
    (their lanes are zero-masked at compute time). Waits rebuild the same
    descriptor, so one per-slot DMA semaphore balances exactly."""
    def one(row, carry):
        s = sel_ref[r, blk, row]

        @pl.when(s == 0)
        def _():
            dma = pltpu.make_async_copy(
                x_ref.at[xrow_ref[r, blk, row], pl.ds(d * bd, bd)],
                sx_ref.at[slot, row], sem_ref.at[slot])
            dma.start() if start else dma.wait()

        @pl.when(s == 1)
        def _():
            src = (tbl_ref.at[trow_ref[r, blk, row]] if full_tbl_row else
                   tbl_ref.at[trow_ref[r, blk, row], pl.ds(d * bd, bd)])
            dma = pltpu.make_async_copy(
                src, st_ref.at[slot, row], sem_ref.at[slot])
            dma.start() if start else dma.wait()

        return carry

    jax.lax.fori_loop(0, bn, one, None)


def _pipelined_block(sel_ref, xrow_ref, trow_ref, selv_ref, x_ref, tbl_ref,
                     vals_ref, out_ref, sx_ref, st_ref, gx_ref, sem_ref,
                     bn, bd, rscl=None, cb_ref=None, nd=1):
    """Shared body of `_kernel` / `_kernel_dq` / `_kernel_vq`:
    double-buffered DMA schedule + route/dequant + MXU accumulate for
    grid step (r, d, k). With `cb_ref` the table holds uint8 vq codes:
    whole code rows are staged (S bytes each) and decoded against the
    resident VMEM codebook via one one-hot matmul per subvector —
    bitwise `core.history.vq_decode_rows` — before the d-block is cut
    out; the f32 halo row is born in VMEM, never in HBM."""
    r = pl.program_id(0)
    d = pl.program_id(1)
    k = pl.program_id(2)
    nk = pl.num_programs(2)
    slot = jax.lax.rem(k, 2)
    vq = cb_ref is not None

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        # warm-up: block 0's rows were never prefetched on this (r, d)
        _row_dmas(sel_ref, xrow_ref, trow_ref, x_ref, tbl_ref, sx_ref,
                  st_ref, sem_ref, r, d, 0, 0, bn, bd, start=True,
                  full_tbl_row=vq)

    # prefetch block k+1's gathered rows into the other slot BEFORE
    # waiting on block k — these DMAs overlap the wait and the MXU work
    @pl.when(k + 1 < nk)
    def _prefetch():
        _row_dmas(sel_ref, xrow_ref, trow_ref, x_ref, tbl_ref, sx_ref,
                  st_ref, sem_ref, r, d, k + 1, jax.lax.rem(k + 1, 2),
                  bn, bd, start=True, full_tbl_row=vq)

    _row_dmas(sel_ref, xrow_ref, trow_ref, x_ref, tbl_ref, sx_ref, st_ref,
              sem_ref, r, d, k, slot, bn, bd, start=False,
              full_tbl_row=vq)

    # route the staged rows: in-batch (sx), halo (st, dequantized for
    # int8/vq tables), or exact zeros — one vectorized select over the bn
    # rows. The staged tile is written to the gx scratch (a rounding
    # barrier keeping numerics identical to the pre-pipelined kernel)
    # before the bn x bn adjacency block contracts it on the MXU.
    selv = selv_ref[0, 0]
    xv = sx_ref[slot].astype(jnp.float32)
    if vq:
        s, c, ds = cb_ref.shape
        codes = st_ref[slot].astype(jnp.int32)             # [bn, S]
        iota_c = jax.lax.broadcasted_iota(jnp.int32, (bn, c), 1)
        parts = [
            jnp.dot((codes[:, sub][:, None] == iota_c).astype(jnp.float32),
                    cb_ref[sub], preferred_element_type=jnp.float32)
            for sub in range(s)]
        rec = jnp.pad(jnp.concatenate(parts, axis=1),
                      ((0, 0), (0, nd * bd - s * ds)))
        tv = jax.lax.dynamic_slice(rec, (0, d * bd), (bn, bd))
    else:
        tv = st_ref[slot].astype(jnp.float32)
    if rscl is not None:
        tv = tv * rscl[:, None]
    gx_ref[...] = jnp.where((selv == 0)[:, None], xv,
                            jnp.where((selv == 1)[:, None], tv, 0.0))
    out_ref[...] += jnp.dot(vals_ref[0, 0], gx_ref[...],
                            preferred_element_type=jnp.float32)


def _make_kernel(bn, bd):
    def _kernel(sel_ref, xrow_ref, trow_ref, selv_ref, x_ref, tbl_ref,
                vals_ref, out_ref, sx_ref, st_ref, gx_ref, sem_ref):
        _pipelined_block(sel_ref, xrow_ref, trow_ref, selv_ref, x_ref,
                         tbl_ref, vals_ref, out_ref, sx_ref, st_ref,
                         gx_ref, sem_ref, bn, bd)
    return _kernel


def _make_kernel_dq(bn, bd):
    def _kernel_dq(sel_ref, xrow_ref, trow_ref, selv_ref, rscl_ref, x_ref,
                   tbl_ref, vals_ref, out_ref, sx_ref, st_ref, gx_ref,
                   sem_ref):
        _pipelined_block(sel_ref, xrow_ref, trow_ref, selv_ref, x_ref,
                         tbl_ref, vals_ref, out_ref, sx_ref, st_ref,
                         gx_ref, sem_ref, bn, bd, rscl=rscl_ref[0, 0])
    return _kernel_dq


def _make_kernel_vq(bn, bd, nd):
    def _kernel_vq(sel_ref, xrow_ref, trow_ref, selv_ref, rscl_ref, x_ref,
                   tbl_ref, vals_ref, cb_ref, out_ref, sx_ref, st_ref,
                   gx_ref, sem_ref):
        _pipelined_block(sel_ref, xrow_ref, trow_ref, selv_ref, x_ref,
                         tbl_ref, vals_ref, out_ref, sx_ref, st_ref,
                         gx_ref, sem_ref, bn, bd, rscl=rscl_ref[0, 0],
                         cb_ref=cb_ref, nd=nd)
    return _kernel_vq


@functools.partial(jax.jit, static_argnames=("bn", "bd", "interpret"))
def gather_spmm(x_in: jnp.ndarray, table: jnp.ndarray,
                blk_vals: jnp.ndarray, blk_cols: jnp.ndarray,
                sel: jnp.ndarray, xrow: jnp.ndarray, trow: jnp.ndarray,
                scales: jnp.ndarray = None,
                codebook: jnp.ndarray = None,
                *, bn: int = 128, bd: int = 128,
                interpret: bool = True) -> jnp.ndarray:
    """out [R*bn, D] = A @ [x_in ; dequant(table)[halo] ; 0] without
    building the bracket. x_in [n_in, D] with D % bd == 0; xrow/trow must
    be pre-clipped to their source's row range (see `gather_plan`). With
    `scales` [N] f32 the table rows are int8 and dequantized in-kernel
    (module docstring); with `codebook` [S, C, ds] too, the table holds
    uint8 vq code rows [N, S] that are staged whole (S bytes per halo
    row) and codebook-decoded in VMEM right before the contraction — the
    codebook rides as a whole-VMEM operand (too big for the SMEM
    scalar-prefetch lane, small enough to stay resident). Output is fp32
    (MXU-native accumulation); the caller casts. The gathered-row
    HBM->VMEM DMAs are double-buffered: block k+1's rows stream while
    block k contracts."""
    R, K, bn_, bn2 = blk_vals.shape
    assert bn_ == bn and bn2 == bn, (blk_vals.shape, bn)
    D = x_in.shape[1]
    assert D % bd == 0, (x_in.shape, bd)
    assert codebook is not None or table.shape[1] == D, (table.shape, D)
    assert sel.shape == (R, K, bn), (sel.shape, (R, K, bn))

    grid = (R, D // bd, K)
    # x_in / table stay whole in HBM (ANY): their rows move via explicit
    # make_async_copy, not BlockSpec-driven pipelining. sel rides twice:
    # as a scalar-prefetch operand (SMEM — drives the per-row DMA
    # conditionals) and as a blocked VMEM operand (the vectorized
    # route/zero select at compute time).
    common_specs = [
        pl.BlockSpec((1, 1, bn), lambda r, d, k, *_: (r, k, 0)),
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec((1, 1, bn, bn), lambda r, d, k, *_: (r, k, 0, 0)),
    ]
    st_width = bd
    if scales is None:
        in_specs = common_specs
        operands = (sel, xrow, trow, sel, x_in, table, blk_vals)
        kernel = _make_kernel(bn, bd)
    else:
        assert scales.shape == (table.shape[0],), (scales.shape,
                                                   table.shape)
        # pre-gathered per-plan-row dequant scales: a dense [R, K, bn]
        # f32 operand (same footprint as the int32 plan arrays) so the
        # dequant multiply is one VPU op over the staged tile
        rscl = jnp.take(scales, trow, mode="clip")
        in_specs = [common_specs[0],
                    pl.BlockSpec((1, 1, bn), lambda r, d, k, *_: (r, k, 0)),
                    *common_specs[1:]]
        if codebook is None:
            operands = (sel, xrow, trow, sel, rscl, x_in, table, blk_vals)
            kernel = _make_kernel_dq(bn, bd)
        else:
            s_, c, ds = codebook.shape
            assert table.shape[1] == s_ and s_ * ds <= D, \
                (table.shape, codebook.shape, D)
            st_width = s_
            in_specs = in_specs + [
                pl.BlockSpec((s_, c, ds),
                             lambda r, d, k, *_: (0, 0, 0))]
            operands = (sel, xrow, trow, sel, rscl, x_in, table,
                        blk_vals, codebook)
            kernel = _make_kernel_vq(bn, bd, D // bd)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bn, bd), lambda r, d, k, *_: (r, d)),
        scratch_shapes=[pltpu.VMEM((2, bn, bd), x_in.dtype),      # sx
                        pltpu.VMEM((2, bn, st_width), table.dtype),  # st
                        pltpu.VMEM((bn, bd), jnp.float32),        # gx
                        pltpu.SemaphoreType.DMA((2,))],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R * bn, D), jnp.float32),
        interpret=interpret,
    )(*operands)
